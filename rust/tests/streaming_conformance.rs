//! Streaming conformance suite (DESIGN.md §5.1): the out-of-core BWKM
//! coordinator (`coordinator::streaming::StreamingBwkm`) is pinned
//! **bit-identical** — `==`, no tolerances — against the in-memory path
//! (`bwkm::run` / `run_auto`) on the same data and seed: same splits
//! (block-for-block identical spatial cells), same representatives and
//! weights, same per-iteration trace (distances, weighted error, Theorem-2
//! bound, boundary sizes), same final centroids, same `DistanceCounter`
//! totals — across chunk sizes {1, 7, n}, worker counts {1, 2, 8} and the
//! Table-1 dimension grid, plus empty-block and single-chunk degenerate
//! cases and a file-backed multi-chunk source (`scripts/ci.sh
//! --streaming` runs this suite; `--quick` runs the `degenerate` subset).
//!
//! Every test is seeded from a named fixed seed (below) or through
//! `util::prop::check`, which derives per-property seeds from the
//! property name and prints the failing case + RNG seed on failure.

use anyhow::Result;
use bwkm::bwkm::{BwkmCfg, BwkmOutcome};
use bwkm::coordinator::{
    stream_partition_stats, stream_partition_stats_with, ChunkCrew, StreamBwkmOutcome,
    StreamingBwkm,
};
use bwkm::data::loader::{save_bin, BinChunks};
use bwkm::data::Dataset;
use bwkm::metrics::DistanceCounter;
use bwkm::partition::Partition;
use bwkm::util::{prop, Rng};

/// Named fixed seeds — quoted in every assertion context so a failure
/// names its reproduction.
const GRID_SEED: u64 = 0x57AB_0001;
const AUTO_SEED: u64 = 0x57AB_0002;
const FILE_SEED: u64 = 0x57AB_0003;
const DEGEN_SEED: u64 = 0x57AB_0004;

fn vec_opener(
    data: Vec<f64>,
    d: usize,
    chunk_rows: usize,
) -> impl FnMut() -> Result<Vec<Result<Vec<f64>>>> {
    let chunk_rows = chunk_rows.max(1);
    move || Ok(data.chunks(chunk_rows * d).map(|c| Ok(c.to_vec())).collect())
}

/// The full `==` pin: centroids, stop reason, distance totals, splits
/// (spatial cells), representatives/weights and the per-iteration trace.
fn assert_conformant(
    ctx: &str,
    mem: &BwkmOutcome,
    mem_distances: u64,
    out: &StreamBwkmOutcome,
    stream_distances: u64,
) {
    assert_eq!(out.centroids, mem.centroids, "{ctx}: centroids");
    assert_eq!(out.stop, mem.stop, "{ctx}: stop reason");
    assert_eq!(stream_distances, mem_distances, "{ctx}: distance totals");
    assert_eq!(out.k, mem.k, "{ctx}: k");
    assert_eq!(out.d, mem.d, "{ctx}: d");

    // Same splits: the spatial trees agree block for block.
    assert_eq!(out.partition.len(), mem.partition.len(), "{ctx}: |B|");
    for (i, (sb, mb)) in
        out.partition.blocks.iter().zip(&mem.partition.blocks).enumerate()
    {
        assert_eq!(sb.cell, mb.cell, "{ctx}: spatial cell of block {i}");
    }

    // Same representative set.
    let (mreps, mweights, mids) = mem.partition.reps_weights();
    assert_eq!(out.reps, mreps, "{ctx}: representatives");
    assert_eq!(out.weights, mweights, "{ctx}: weights");
    assert_eq!(out.ids, mids, "{ctx}: block ids");

    // Same trace, bit for bit.
    assert_eq!(out.trace.len(), mem.trace.len(), "{ctx}: trace length");
    for (row, (a, b)) in out.trace.iter().zip(&mem.trace).enumerate() {
        assert_eq!(a.outer_iter, b.outer_iter, "{ctx}: trace[{row}]");
        assert_eq!(a.distances, b.distances, "{ctx}: trace[{row}] distances");
        assert_eq!(a.blocks, b.blocks, "{ctx}: trace[{row}] blocks");
        assert_eq!(a.occupied, b.occupied, "{ctx}: trace[{row}] occupied");
        assert_eq!(a.boundary, b.boundary, "{ctx}: trace[{row}] boundary");
        assert_eq!(
            a.weighted_error.to_bits(),
            b.weighted_error.to_bits(),
            "{ctx}: trace[{row}] weighted error"
        );
        assert_eq!(a.bound.to_bits(), b.bound.to_bits(), "{ctx}: trace[{row}] bound");
        assert_eq!(
            a.full_error.map(f64::to_bits),
            b.full_error.map(f64::to_bits),
            "{ctx}: trace[{row}] full error"
        );
        assert_eq!(a.lloyd_iters, b.lloyd_iters, "{ctx}: trace[{row}] lloyd iters");
    }
}

#[test]
fn grid_dims_chunks_workers_bit_identical() {
    // The Table-1 dimension grid the engine monomorphizes for (2, 3, 5,
    // 17) × chunk sizes {1, 7, n} × worker counts {1, 2, 8}.
    for &(d, k) in &[(2usize, 4usize), (3, 3), (5, 3), (17, 2)] {
        let n = 240;
        let mut g = prop::Gen { rng: Rng::new(GRID_SEED ^ d as u64), case: 0 };
        let ds = Dataset::new(g.blobs(n, d, k, 0.7), d);
        let mut cfg = BwkmCfg::for_dataset(n, d, k);
        cfg.max_outer = 5;

        let c_mem = DistanceCounter::new();
        let mem = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(GRID_SEED), &c_mem);

        for &chunk in &[1usize, 7, n] {
            for &workers in &[1usize, 2, 8] {
                let ctx = format!(
                    "seed {GRID_SEED:#x}, d={d} k={k} chunk={chunk} workers={workers}"
                );
                let c_str = DistanceCounter::new();
                let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), d, chunk), d)
                    .with_threads(workers);
                let out = sb
                    .run(k, &cfg, &mut Rng::new(GRID_SEED), &c_str)
                    .unwrap_or_else(|e| panic!("{ctx}: run failed: {e}"));
                assert_conformant(&ctx, &mem, c_mem.get(), &out, c_str.get());
            }
        }
    }
}

#[test]
fn prop_streaming_conformance_random() {
    prop::check("streaming-conformance", 6, |g| {
        let n = g.int(30, 240);
        let d = g.int(1, 6);
        let k = g.int(1, 4).min(n);
        let ds = Dataset::new(g.blobs(n, d, k.max(2), 0.8), d);
        let mut cfg = BwkmCfg::for_dataset(n, d, k);
        cfg.max_outer = g.int(1, 4);
        cfg.eval_full_error = g.case % 2 == 0;
        let chunk = [1, 7, n][g.int(0, 2)];
        let workers = g.int(1, 8);
        let seed = g.rng.next_u64();
        let ctx = format!(
            "case {} (seed {seed:#x}): n={n} d={d} k={k} chunk={chunk} workers={workers}",
            g.case
        );

        let c_mem = DistanceCounter::new();
        let mem = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(seed), &c_mem);
        let c_str = DistanceCounter::new();
        let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), d, chunk), d)
            .with_threads(workers);
        let out = sb
            .run(k, &cfg, &mut Rng::new(seed), &c_str)
            .unwrap_or_else(|e| panic!("{ctx}: run failed: {e}"));
        assert_conformant(&ctx, &mem, c_mem.get(), &out, c_str.get());
    });
}

#[test]
fn auto_engine_conformance_including_choice_log() {
    // run_auto both sides: the auto-selected engine family is
    // bit-identical too, the (smaller) bill matches exactly, and the
    // per-step choice notes agree — the streaming path reproduces not
    // just the answer but the engine decisions.
    let (n, d, k) = (420, 3, 5);
    let mut g = prop::Gen { rng: Rng::new(AUTO_SEED), case: 0 };
    let ds = Dataset::new(g.blobs(n, d, k, 0.6), d);
    let mut cfg = BwkmCfg::for_dataset(n, d, k);
    cfg.max_outer = 6;

    let c_mem = DistanceCounter::new();
    let mem = bwkm::bwkm::run_auto(&ds, k, &cfg, &mut Rng::new(AUTO_SEED), &c_mem);
    let c_str = DistanceCounter::new();
    let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), d, 61), d).with_threads(2);
    let out = sb.run_auto(k, &cfg, &mut Rng::new(AUTO_SEED), &c_str).unwrap();

    assert_conformant(
        &format!("seed {AUTO_SEED:#x}: auto engine"),
        &mem,
        c_mem.get(),
        &out,
        c_str.get(),
    );
    assert_eq!(
        c_str.notes(),
        c_mem.notes(),
        "seed {AUTO_SEED:#x}: per-step auto choices must match"
    );
}

#[test]
fn file_backed_multi_chunk_conformance() {
    // The whole pipeline against a real on-disk binary source split into
    // many chunks (this is the test `scripts/ci.sh --streaming` names).
    let (n, d, k) = (500, 3, 4);
    let mut g = prop::Gen { rng: Rng::new(FILE_SEED), case: 0 };
    let ds = Dataset::new(g.blobs(n, d, k, 0.5), d);
    let path = std::env::temp_dir()
        .join(format!("bwkm_stream_conf_{}.bin", std::process::id()));
    save_bin(&ds, &path).unwrap();

    let mut cfg = BwkmCfg::for_dataset(n, d, k);
    cfg.max_outer = 5;
    let c_mem = DistanceCounter::new();
    let mem = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(FILE_SEED), &c_mem);

    for &(chunk_rows, workers) in &[(64usize, 2usize), (97, 4)] {
        let ctx = format!(
            "seed {FILE_SEED:#x}: file-backed chunk_rows={chunk_rows} workers={workers}"
        );
        let chunks = BinChunks::open(&path, chunk_rows).unwrap();
        assert!(
            (n + chunk_rows - 1) / chunk_rows >= 4,
            "{ctx}: want a genuinely multi-chunk file"
        );
        drop(chunks);
        let c_str = DistanceCounter::new();
        let mut sb = StreamingBwkm::new(BinChunks::opener(&path, chunk_rows), d)
            .with_threads(workers);
        let out = sb
            .run(k, &cfg, &mut Rng::new(FILE_SEED), &c_str)
            .unwrap_or_else(|e| panic!("{ctx}: run failed: {e}"));
        assert_conformant(&ctx, &mem, c_mem.get(), &out, c_str.get());
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn degenerate_single_chunk_source_is_conformant() {
    // chunk ≥ n: the whole stream arrives as one chunk (and one larger
    // than the stream), workers both idle and active.
    let (n, d, k) = (150, 3, 3);
    let mut g = prop::Gen { rng: Rng::new(DEGEN_SEED), case: 0 };
    let ds = Dataset::new(g.blobs(n, d, k, 0.6), d);
    let mut cfg = BwkmCfg::for_dataset(n, d, k);
    cfg.max_outer = 4;
    let c_mem = DistanceCounter::new();
    let mem = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(DEGEN_SEED), &c_mem);
    for &chunk in &[n, n + 999] {
        for &workers in &[1usize, 4] {
            let ctx = format!(
                "seed {DEGEN_SEED:#x}: single-chunk chunk={chunk} workers={workers}"
            );
            let c_str = DistanceCounter::new();
            let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), d, chunk), d)
                .with_threads(workers);
            let out = sb.run(k, &cfg, &mut Rng::new(DEGEN_SEED), &c_str).unwrap();
            assert_conformant(&ctx, &mem, c_mem.get(), &out, c_str.get());
        }
    }
}

#[test]
fn degenerate_empty_block_statistics_match_in_memory() {
    // The split rule never creates empty blocks (a tight-box midpoint has
    // members on both sides), so force one with an off-data plane and pin
    // the streamed statistics against a full in-memory rebuild: zero
    // count, zero sums, no tight box, skipped by reps_weights — for every
    // crew size.
    let ds = Dataset::new(
        vec![0.0, 0.0, 1.0, 0.5, 0.25, 0.75, 0.9, 0.1, 0.4, 0.6],
        2,
    );
    let mut p = Partition::root(&ds);
    p.split_at(0, 0, 50.0, Some(&ds)); // right child far beyond the data
    p.split(0, &ds);
    let mut rebuilt = p.clone();
    rebuilt.assign_members(&ds);

    let chunks =
        || ds.data.chunks(2).map(|c| Ok(c.to_vec())).collect::<Vec<Result<Vec<f64>>>>();
    let base = stream_partition_stats(&p, 2, chunks()).unwrap();
    for threads in [1usize, 2, 8] {
        let ctx = format!("empty-block crew={threads}");
        let stats =
            stream_partition_stats_with(&p, 2, chunks(), &ChunkCrew::new(threads)).unwrap();
        assert_eq!(stats.counts, base.counts, "{ctx}");
        for (b, blk) in rebuilt.blocks.iter().enumerate() {
            assert_eq!(stats.counts[b], blk.weight(), "{ctx}: block {b} count");
            assert_eq!(stats.tight[b], blk.tight, "{ctx}: block {b} tight");
            for j in 0..2 {
                assert_eq!(
                    stats.sums[b][j].to_bits(),
                    blk.sum[j].to_bits(),
                    "{ctx}: block {b} sum[{j}]"
                );
            }
        }
        let (reps, weights, ids) = stats.reps_weights(2);
        let (rreps, rweights, rids) = rebuilt.reps_weights();
        assert_eq!(reps, rreps, "{ctx}: reps skip the empty block");
        assert_eq!(weights, rweights, "{ctx}");
        assert_eq!(ids, rids, "{ctx}");
    }
}

#[test]
fn degenerate_identical_points_conformant() {
    // Zero-diameter everything: the cutting rule has no mass anywhere,
    // kmeans++ falls back to weight-proportional draws, the boundary
    // empties immediately — both paths must walk the identical degenerate
    // route.
    let ds = Dataset::new(vec![1.5; 120], 2); // 60 identical 2-d points
    let k = 2;
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
    cfg.max_outer = 4;
    let c_mem = DistanceCounter::new();
    let mem = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(DEGEN_SEED), &c_mem);
    let c_str = DistanceCounter::new();
    let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), 2, 7), 2).with_threads(2);
    let out = sb.run(k, &cfg, &mut Rng::new(DEGEN_SEED), &c_str).unwrap();
    assert_conformant(
        &format!("seed {DEGEN_SEED:#x}: identical points"),
        &mem,
        c_mem.get(),
        &out,
        c_str.get(),
    );
    assert!(out.centroids.iter().all(|&x| (x - 1.5).abs() < 1e-12));
}

#[test]
fn passes_stay_bounded_by_refinement_rounds() {
    // Memory/pass accounting sanity: the pass count is O(split rounds +
    // sample rounds + evals), never O(n) — the whole point of doing all
    // expensive work on the representative set.
    let (n, d, k) = (300, 2, 3);
    let mut g = prop::Gen { rng: Rng::new(GRID_SEED ^ 0xff), case: 0 };
    let ds = Dataset::new(g.blobs(n, d, k, 0.5), d);
    let mut cfg = BwkmCfg::for_dataset(n, d, k);
    cfg.max_outer = 6;
    let c = DistanceCounter::new();
    let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), d, 32), d);
    let out = sb.run(k, &cfg, &mut Rng::new(GRID_SEED), &c).unwrap();
    // Per outer iteration at most one refresh; init needs O(log m) split
    // rounds with a fetch + refresh each, plus r fetches per Alg. 2 round.
    let m = cfg.init.m;
    let generous = 3 + 2 * (m + cfg.init.r * m) + 2 * cfg.max_outer;
    assert!(
        out.passes <= generous,
        "pass count {} exploded (bound {generous})",
        out.passes
    );
}
