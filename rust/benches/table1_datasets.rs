//! Exp T1 — regenerate the paper's Table 1 (dataset inventory), plus the
//! simulator characteristics at the bench scale: per-dataset n, d,
//! bounding-box diagonal and generation throughput.

use bwkm::bench::{bench_secs, env_f64, write_csv};
use bwkm::data::{simulate, TABLE1};
use bwkm::geometry::BBox;
use bwkm::util::fmt_count;

fn main() {
    let scale = 0.002 * env_f64("BWKM_SCALE", 1.0);
    println!("=== Table 1: datasets (paper n vs simulated n at scale) ===");
    println!(
        "{:<6} {:>12} {:>4} {:>10} {:>12} {:>10}",
        "name", "paper n", "d", "sim n", "bbox diag", "gen (s)"
    );
    let mut rows = vec![vec![
        "name".into(),
        "paper_n".into(),
        "d".into(),
        "sim_n".into(),
        "diag".into(),
        "gen_secs".into(),
    ]];
    for spec in TABLE1 {
        let mut ds = simulate(spec.name, scale, 1).unwrap();
        let secs = bench_secs(1, || {
            ds = simulate(spec.name, scale, 1).unwrap();
        });
        let diag = BBox::of(&ds.data, ds.d, None).unwrap().diagonal();
        println!(
            "{:<6} {:>12} {:>4} {:>10} {:>12.3} {:>10.3}",
            spec.name,
            fmt_count(spec.paper_n as u64),
            spec.d,
            fmt_count(ds.n as u64),
            diag,
            secs
        );
        rows.push(vec![
            spec.name.into(),
            spec.paper_n.to_string(),
            spec.d.to_string(),
            ds.n.to_string(),
            format!("{diag:.4}"),
            format!("{secs:.4}"),
        ]);
    }
    write_csv("table1_datasets", &rows);
}
