//! Exp O1 — telemetry overhead (DESIGN.md §2.11): what does watching a
//! run cost, and is the off path really free?
//!
//! Two measurements:
//!
//! 1. **Record path** — raw throughput of the `Recorder` hot calls
//!    (counter/gauge/span) against each sink: off (no-op), null (sink
//!    dispatch only), summary (mutex + BTreeMap fold), jsonl (buffered
//!    append). The off path must be within noise of an empty loop — it
//!    takes no clock reading and touches no allocation.
//! 2. **Whole run** — the same seeded BWKM run under metrics off vs
//!    jsonl, asserting the §2.11 non-perturbation contract (`==` on
//!    centroids and the distance bill) while measuring the wall-clock
//!    delta an instrumented run pays end to end.
//!
//! Emits `BENCH_obs_overhead.json` (typed cells).

use bwkm::bench::{bench_secs, env_f64, write_bench_json, Cell};
use bwkm::data::simulate;
use bwkm::metrics::DistanceCounter;
use bwkm::obs::Recorder;
use bwkm::util::{fmt_count, Rng};

const RECORDS: usize = 100_000;

/// Seconds per `RECORDS` mixed counter/gauge/span records against `rec`.
fn record_path_secs(rec: &Recorder) -> f64 {
    bench_secs(3, || {
        for i in 0..RECORDS as u64 {
            match i % 3 {
                0 => rec.counter("bench.counter", i),
                1 => rec.gauge("bench.gauge", i as f64),
                _ => drop(rec.span("bench.span")),
            }
        }
        std::hint::black_box(rec);
    })
}

fn main() {
    let mult = env_f64("BWKM_SCALE", 1.0);
    println!("=== O1: telemetry overhead ({} records/iter) ===", fmt_count(RECORDS as u64));

    // ---- 1. Record-path throughput per sink.
    let trace = std::env::temp_dir().join(format!("bwkm_bench_obs_{}.jsonl", std::process::id()));
    let sinks: Vec<(&str, Recorder)> = vec![
        ("off", Recorder::off()),
        ("null", Recorder::null()),
        ("summary", Recorder::summary()),
        ("jsonl", Recorder::jsonl(&trace).expect("open trace")),
    ];
    let mut rows = Vec::new();
    println!("{:<10} {:>14} {:>14}", "sink", "secs/iter", "records/s");
    for (name, rec) in &sinks {
        let secs = record_path_secs(rec);
        let rate = if secs > 0.0 { RECORDS as f64 / secs } else { f64::INFINITY };
        println!("{name:<10} {secs:>14.6} {:>14}", fmt_count(rate as u64));
        rows.push(vec![
            ("bench".to_string(), Cell::from("record_path")),
            ("sink".to_string(), Cell::from(*name)),
            ("secs".to_string(), Cell::F64(secs)),
            ("records_per_s".to_string(), Cell::F64(rate)),
        ]);
    }
    drop(sinks);
    std::fs::remove_file(&trace).ok();

    // ---- 2. Whole-run overhead: off vs jsonl on the same seeded run.
    let ds = simulate("WUY", (0.002 * mult).min(1.0), 31).expect("simulator");
    let k = 9;
    let cfg = bwkm::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, k);

    let c_off = DistanceCounter::new();
    let mut out_off = None;
    let t_off = bench_secs(3, || {
        c_off.reset();
        out_off = Some(bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(5), &c_off));
    });
    let out_off = out_off.expect("ran");

    let c_rec = DistanceCounter::new();
    let mut out_rec = None;
    let t_rec = bench_secs(3, || {
        c_rec.reset();
        let rec = Recorder::jsonl(&trace).expect("open trace");
        out_rec =
            Some(bwkm::bwkm::run_rec(&ds, k, &cfg, &mut Rng::new(5), &c_rec, &rec));
        rec.flush();
    });
    let out_rec = out_rec.expect("ran");
    std::fs::remove_file(&trace).ok();

    // §2.11 non-perturbation: the instrumented run is the same run.
    assert_eq!(out_off.centroids, out_rec.centroids, "jsonl telemetry perturbed the centroids");
    assert_eq!(c_off.get(), c_rec.get(), "jsonl telemetry perturbed the distance bill");

    let overhead = if t_off > 0.0 { (t_rec - t_off) / t_off * 100.0 } else { 0.0 };
    println!(
        "bwkm run (n={} d={} k={k}): off={t_off:.4}s jsonl={t_rec:.4}s overhead={overhead:+.1}%",
        ds.n, ds.d
    );
    rows.push(vec![
        ("bench".to_string(), Cell::from("whole_run")),
        ("n".to_string(), Cell::U64(ds.n as u64)),
        ("off_secs".to_string(), Cell::F64(t_off)),
        ("jsonl_secs".to_string(), Cell::F64(t_rec)),
        ("overhead_pct".to_string(), Cell::F64(overhead)),
        ("bit_identical".to_string(), Cell::from("true")),
    ]);

    write_bench_json("obs_overhead", &rows);
}
