//! Exp S1 — out-of-core BWKM scaling (DESIGN.md §5.1): streamed-pass
//! throughput and full-run wall time across chunk sizes and chunk-worker
//! counts, with the in-memory run as the baseline the streamed one must
//! (and does — asserted per row) equal bit for bit. Columns: statistics
//! pass rows/s, full streamed run wall time and pass count, in-memory
//! wall time, bit-identity flag.

use bwkm::bench::{bench_secs, env_f64, write_csv};
use bwkm::coordinator::{stream_partition_stats_with, ChunkCrew, StreamingBwkm};
use bwkm::data::loader::{save_bin, BinChunks};
use bwkm::data::simulate;
use bwkm::metrics::DistanceCounter;
use bwkm::util::{fmt_count, Rng};

fn main() {
    let mult = env_f64("BWKM_SCALE", 1.0);
    let k = 9;
    let seed = 5;
    let ds = simulate("WUY", (0.01 * mult).min(1.0), 31).expect("simulator");
    let (n, d) = (ds.n, ds.d);
    let path = std::env::temp_dir().join(format!("bwkm_bench_stream_{}.bin", std::process::id()));
    save_bin(&ds, &path).expect("write bench source");
    println!("=== S1: out-of-core BWKM ({} rows x {d} dims, k={k}) ===", fmt_count(n as u64));

    // Baseline: the in-memory run the streamed one must reproduce.
    let cfg = bwkm::bwkm::BwkmCfg::for_dataset(n, d, k);
    let c_mem = DistanceCounter::new();
    let t_mem = bench_secs(1, || {
        c_mem.reset();
        std::hint::black_box(bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(seed), &c_mem));
    });
    let mem = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(seed), &DistanceCounter::new());
    println!("in-memory bwkm::run: {t_mem:.3}s, {} distances", fmt_count(c_mem.get()));

    println!(
        "{:<22} {:>14} {:>12} {:>8} {:>12}",
        "chunk_rows,threads", "pass rows/s", "run wall", "passes", "bit-identical"
    );
    let mut rows = vec![vec![
        "chunk_rows".into(),
        "threads".into(),
        "pass_rows_per_s".into(),
        "run_secs".into(),
        "passes".into(),
        "mem_secs".into(),
        "bit_identical".into(),
    ]];
    for &chunk_rows in &[1024usize, 8192] {
        for &threads in &[1usize, 2, 4, 8] {
            // Statistics-pass throughput over the final in-memory
            // partition (the per-refinement cost of §5.1).
            let crew = ChunkCrew::new(threads);
            let t_pass = bench_secs(3, || {
                let chunks = BinChunks::open(&path, chunk_rows).expect("open");
                std::hint::black_box(
                    stream_partition_stats_with(&mem.partition, d, chunks, &crew).expect("pass"),
                );
            });
            let pass_rows_s = n as f64 / t_pass;

            // Full streamed run.
            let c_str = DistanceCounter::new();
            let mut out = None;
            let t_run = bench_secs(1, || {
                c_str.reset();
                let mut sb = StreamingBwkm::new(BinChunks::opener(&path, chunk_rows), d)
                    .with_threads(threads);
                out = Some(sb.run(k, &cfg, &mut Rng::new(seed), &c_str).expect("stream run"));
            });
            let out = out.expect("ran");
            let identical =
                out.centroids == mem.centroids && c_str.get() == c_mem.get();
            assert!(identical, "streamed run diverged at chunk={chunk_rows} threads={threads}");
            println!(
                "{:<22} {:>14} {:>11.3}s {:>8} {:>12}",
                format!("{chunk_rows},{threads}"),
                fmt_count(pass_rows_s as u64),
                t_run,
                out.passes,
                identical
            );
            rows.push(vec![
                chunk_rows.to_string(),
                threads.to_string(),
                format!("{pass_rows_s:.0}"),
                format!("{t_run:.4}"),
                out.passes.to_string(),
                format!("{t_mem:.4}"),
                identical.to_string(),
            ]);
        }
    }
    write_csv("streaming_scale", &rows);
    std::fs::remove_file(&path).ok();
}
