//! Exp A4 — Theorem A.1: the grid-RPKM representation is a (K, ε)-coreset
//! with ε decaying exponentially in the grid level. Reports, per level,
//! the theoretical bound and the measured |E^D − E^P| gap for K-means++
//! centroids, on a synthetic GMM.

use bwkm::bench::write_csv;
use bwkm::coreset::{empirical_gap, grid_abs_bound, grid_epsilon};
use bwkm::data::synthetic::random_blobs;
use bwkm::geometry::BBox;
use bwkm::kmeans::init::kmeanspp;
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::rpkm::grid_partition;
use bwkm::util::Rng;

fn main() {
    let mut rng = Rng::new(19);
    let n = 20_000;
    let ds = {
        let d = random_blobs(&mut rng, n, 3, 5, 0.8, 0.5);
        bwkm::data::Dataset::new(d.data, 3)
    };
    let bbox = BBox::of(&ds.data, ds.d, None).unwrap();
    let l = bbox.diagonal();
    let c = DistanceCounter::new();
    let cents = kmeanspp(&ds.data, ds.d, 5, &mut rng, &c);
    let e_full = kmeans_error(&ds.data, ds.d, &cents, &c);

    println!("=== Thm A.1: grid-RPKM coreset bound (n={n}, d=3, K=5) ===");
    println!(
        "{:<6} {:>10} {:>14} {:>14} {:>12}",
        "level", "|P|", "gap |E^D-E^P|", "abs bound", "eps(OPT~)"
    );
    let mut rows = vec![vec![
        "level".into(),
        "reps".into(),
        "gap".into(),
        "bound".into(),
        "epsilon".into(),
    ]];
    let mut gaps = Vec::new();
    for level in 1..=7u32 {
        let (reps, weights) = grid_partition(&ds, &bbox, level);
        let gap = empirical_gap(&ds.data, ds.d, &reps, &weights, &cents);
        let bound = grid_abs_bound(level, n, l);
        // OPT is unknown; use the best error we have as its stand-in for
        // the ε report (the paper's ε also divides by OPT).
        let eps = grid_epsilon(level, n, l, e_full);
        println!(
            "{:<6} {:>10} {:>14.4e} {:>14.4e} {:>12.4}",
            level,
            weights.len(),
            gap,
            bound,
            eps
        );
        assert!(gap <= bound, "Theorem A.1 violated at level {level}");
        gaps.push(gap);
        rows.push(vec![
            level.to_string(),
            weights.len().to_string(),
            format!("{gap:.6e}"),
            format!("{bound:.6e}"),
            format!("{eps:.6}"),
        ]);
    }
    // The *bound* decays exponentially (that is the theorem); the raw gap
    // only needs to end far below where it started.
    assert!(
        gaps.last().unwrap() < &(gaps[0] / 4.0),
        "refinement did not shrink the gap: {gaps:?}"
    );
    write_csv("coreset_bound", &rows);
}
