//! Exp A2 — ablation of the §2.2 initial partition (Alg. 2–4) vs a
//! dataset-independent uniform start of the same size, on the CIF
//! simulator (the paper's hardest regime: small n, high d), K = 9.
//!
//! Expected shape: the boundary-seeking initial partition yields a lower
//! error at the same partition size / distance budget because its blocks
//! concentrate where cluster affiliation is ambiguous (§2.2's motivation).

use bwkm::bwkm::{initial_partition, starting_partition, InitCfg};
use bwkm::bench::{env_f64, env_u64, write_csv};
use bwkm::data::simulate;
use bwkm::kmeans::init::weighted_kmeanspp;
use bwkm::kmeans::{weighted_lloyd, WLloydCfg};
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::util::{fmt_count, Rng};

const K: usize = 9;

fn main() {
    let scale = 0.3 * env_f64("BWKM_SCALE", 1.0);
    let reps = env_u64("BWKM_REPS", 3);
    let ds = simulate("CIF", scale, 13).unwrap();
    let m = (10.0 * ((K * ds.d) as f64).sqrt()).ceil() as usize;
    let s = (ds.n as f64).sqrt().ceil() as usize;
    println!("=== Ablation A2: initial partition (CIF sim, n={}, m={m}) ===", ds.n);
    println!("{:<22} {:>14} {:>12} {:>8}", "initialization", "distances", "E^D", "|P|");

    let mut rows = vec![vec![
        "init".into(),
        "rep".into(),
        "distances".into(),
        "error".into(),
        "occupied".into(),
    ]];
    for rep in 0..reps {
        // --- Alg. 2 (misassignment-guided).
        let c = DistanceCounter::new();
        let cfg = InitCfg { m_prime: (m / 4).max(K + 1), m, s, r: 5 };
        let mut rng = Rng::new(200 + rep);
        let p = initial_partition(&ds, K, &cfg, &mut rng, &c);
        let (e, occ) = finish(&ds, &p, &mut rng, &c);
        emit_row(&mut rows, "Alg.2 (boundary)", rep, c.get(), e, occ);

        // --- Size-only (Alg. 3 run all the way to m: dataset-aware density
        // splitting but no misassignment information).
        let c = DistanceCounter::new();
        let mut rng = Rng::new(200 + rep);
        let mut p = starting_partition(&ds, m, s, &mut rng);
        p.assign_members(&ds);
        let (e, occ) = finish(&ds, &p, &mut rng, &c);
        emit_row(&mut rows, "Alg.3-only (density)", rep, c.get(), e, occ);
    }
    write_csv("ablation_init", &rows);
}

fn finish(
    ds: &bwkm::data::Dataset,
    p: &bwkm::partition::Partition,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> (f64, usize) {
    let (reps, weights, _) = p.reps_weights();
    let cents = weighted_kmeanspp(&reps, &weights, ds.d, K, rng, counter);
    let out = weighted_lloyd(&reps, &weights, ds.d, &cents, &WLloydCfg::default(), counter);
    let eval = DistanceCounter::new();
    (kmeans_error(&ds.data, ds.d, &out.centroids, &eval), p.occupied())
}

fn emit_row(rows: &mut Vec<Vec<String>>, name: &str, rep: u64, d: u64, e: f64, occ: usize) {
    println!("{:<22} {:>14} {:>12.5e} {:>8}", name, fmt_count(d), e, occ);
    rows.push(vec![
        name.into(),
        rep.to_string(),
        d.to_string(),
        format!("{e:.8e}"),
        occ.to_string(),
    ]);
}
