//! Exp A2 — two initialization ablations on the CIF simulator (the
//! paper's hardest regime: small n, high d), K = 9:
//!
//! * **Partition ablation** (§2.2): the misassignment-guided Alg. 2
//!   initial partition vs a dataset-aware-but-boundary-blind Alg. 3 run
//!   of the same size. Expected shape: the boundary-seeking partition
//!   yields a lower error at the same partition size / distance budget.
//! * **Seeding ablation** (DESIGN.md §2.8): all four `Seeder` backends —
//!   Forgy, K-means++, AFK-MC², K-means|| — over the same Alg. 2
//!   representative set, reporting each method's own seeding bill, the
//!   total distances after the weighted-Lloyd polish, and the final E^D:
//!   the distances-vs-quality trade-off K-means|| exists to move
//!   (O(r) engine passes instead of K serial ones).

use bwkm::bwkm::{initial_partition, starting_partition, InitCfg};
use bwkm::bench::{env_f64, env_u64, write_csv};
use bwkm::data::simulate;
use bwkm::kmeans::init::{SeedMethod, SeedPolicy, Seeder as _};
use bwkm::kmeans::{weighted_lloyd, WLloydCfg};
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::util::{fmt_count, Rng};

const K: usize = 9;

fn main() {
    let scale = 0.3 * env_f64("BWKM_SCALE", 1.0);
    let reps = env_u64("BWKM_REPS", 3);
    let ds = simulate("CIF", scale, 13).unwrap();
    let m = (10.0 * ((K * ds.d) as f64).sqrt()).ceil() as usize;
    let s = (ds.n as f64).sqrt().ceil() as usize;
    println!("=== Ablation A2: initial partition (CIF sim, n={}, m={m}) ===", ds.n);
    println!("{:<22} {:>14} {:>12} {:>8}", "initialization", "distances", "E^D", "|P|");

    let mut rows = vec![vec![
        "init".into(),
        "rep".into(),
        "distances".into(),
        "error".into(),
        "occupied".into(),
    ]];
    for rep in 0..reps {
        // --- Alg. 2 (misassignment-guided).
        let c = DistanceCounter::new();
        let cfg = InitCfg { m_prime: (m / 4).max(K + 1), m, s, r: 5 };
        let mut rng = Rng::new(200 + rep);
        let p = initial_partition(&ds, K, &cfg, &mut rng, &c);
        let (e, occ) = finish(&ds, &p, &mut rng, &c);
        emit_row(&mut rows, "Alg.2 (boundary)", rep, c.get(), e, occ);

        // --- Size-only (Alg. 3 run all the way to m: dataset-aware density
        // splitting but no misassignment information).
        let c = DistanceCounter::new();
        let mut rng = Rng::new(200 + rep);
        let mut p = starting_partition(&ds, m, s, &mut rng);
        p.assign_members(&ds);
        let (e, occ) = finish(&ds, &p, &mut rng, &c);
        emit_row(&mut rows, "Alg.3-only (density)", rep, c.get(), e, occ);
    }
    write_csv("ablation_init", &rows);

    // --- Seeding ablation: the §2.8 backends over one Alg. 2 partition.
    println!("\n=== Ablation A2b: Seeder backends over the Alg.2 reps ===");
    println!(
        "{:<8} {:>14} {:>14} {:>12} {:>8}",
        "seeding", "seed dists", "total dists", "E^D", "iters"
    );
    let mut srows = vec![vec![
        "seeding".into(),
        "rep".into(),
        "seed_distances".into(),
        "total_distances".into(),
        "error".into(),
        "lloyd_iters".into(),
    ]];
    for rep in 0..reps {
        // One partition per repetition, shared by every seeding method so
        // the only variable is the seeder.
        let cfg = InitCfg { m_prime: (m / 4).max(K + 1), m, s, r: 5 };
        let c_part = DistanceCounter::new();
        let mut rng = Rng::new(400 + rep);
        let p = initial_partition(&ds, K, &cfg, &mut rng, &c_part);
        let (preps, pweights, _) = p.reps_weights();

        for method in [SeedMethod::Forgy, SeedMethod::Kmpp, SeedMethod::Kmc2, SeedMethod::Par] {
            let policy = SeedPolicy::of(method);
            let mut seeder = policy.seeder();
            let c = DistanceCounter::new();
            let mut rng = Rng::new(500 + rep);
            let cents = seeder.seed(&preps, &pweights, ds.d, K, &mut rng, &c);
            let seed_d = c.get();
            let out =
                weighted_lloyd(&preps, &pweights, ds.d, &cents, &WLloydCfg::default(), &c);
            let eval = DistanceCounter::new();
            let e = kmeans_error(&ds.data, ds.d, &out.centroids, &eval);
            println!(
                "{:<8} {:>14} {:>14} {:>12.5e} {:>8}",
                seeder.name(),
                fmt_count(seed_d),
                fmt_count(c.get()),
                e,
                out.iters
            );
            srows.push(vec![
                seeder.name().into(),
                rep.to_string(),
                seed_d.to_string(),
                c.get().to_string(),
                format!("{e:.8e}"),
                out.iters.to_string(),
            ]);
        }
    }
    write_csv("ablation_init_seeding", &srows);
}

fn finish(
    ds: &bwkm::data::Dataset,
    p: &bwkm::partition::Partition,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> (f64, usize) {
    let (reps, weights, _) = p.reps_weights();
    // The default §2.8 policy (weighted K-means++) — the Alg. 5 Step-1
    // seeding both partition variants share.
    let cents =
        SeedPolicy::default().seeder().seed(&reps, &weights, ds.d, K, rng, counter);
    let out = weighted_lloyd(&reps, &weights, ds.d, &cents, &WLloydCfg::default(), counter);
    let eval = DistanceCounter::new();
    (kmeans_error(&ds.data, ds.d, &out.centroids, &eval), p.occupied())
}

fn emit_row(rows: &mut Vec<Vec<String>>, name: &str, rep: u64, d: u64, e: f64, occ: usize) {
    println!("{:<22} {:>14} {:>12.5e} {:>8}", name, fmt_count(d), e, occ);
    rows.push(vec![
        name.into(),
        rep.to_string(),
        d.to_string(),
        format!("{e:.8e}"),
        occ.to_string(),
    ]);
}
