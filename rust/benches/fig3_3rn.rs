//! Exp F-series — regenerate the paper's Figure for the 3RN dataset:
//! distance computations vs relative error (Eq. 6) for every method,
//! K ∈ {3, 9, 27}. See DESIGN.md §3 and EXPERIMENTS.md for the
//! paper-vs-measured comparison. Scale via BWKM_SCALE / BWKM_REPS.

use bwkm::bench::figures::{emit, run_figure, FigureCfg};

fn main() {
    let cfg = FigureCfg::for_dataset("3RN", 0.05);
    let res = run_figure(&cfg);
    emit(&res, "fig3_3rn");
}
