//! Exp A3 — distance pruning inside weighted Lloyd (the paper's §4
//! future-work integration, refs [13]/[15]): plain vs Hamerly-pruned vs
//! the engine's cross-iteration bounded backend (which now also powers
//! `kmeans::elkan`) vs the auto-selecting backend, over the
//! representatives of a BWKM-like partition of the GS simulator, K = 27.
//! Reports distances actually computed, the reduction factor ([15]
//! reports >80% on favourable data), the bounded backend's per-warm-step
//! prune rate, and the per-step engine choices `AutoAssigner` logged on
//! its counter (DESIGN.md §2.7).

use bwkm::bench::{env_f64, write_bench_json, write_csv, Cell};
use bwkm::bwkm::{initial_partition, InitCfg};
use bwkm::data::simulate;
use bwkm::kmeans::assign::AutoAssigner;
use bwkm::kmeans::elkan::elkan_weighted_lloyd;
use bwkm::kmeans::init::weighted_kmeanspp;
use bwkm::kmeans::pruning::pruned_weighted_lloyd;
use bwkm::kmeans::{
    stepper_for, weighted_lloyd, weighted_lloyd_with, AssignCfg, AssignMode, EngineStepper,
    Stepper, WLloydCfg,
};
use bwkm::metrics::DistanceCounter;
use bwkm::util::{fmt_count, Rng};

const K: usize = 27;

fn main() {
    let scale = 0.005 * env_f64("BWKM_SCALE", 1.0);
    let ds = simulate("GS", scale, 17).unwrap();
    let mut rng = Rng::new(5);
    let c0 = DistanceCounter::new();
    // A realistic representative set: BWKM's initial partition at 4x the
    // default size (more reps = more pruning opportunity).
    let m = 4 * (10.0 * ((K * ds.d) as f64).sqrt()).ceil() as usize;
    let cfg = InitCfg { m_prime: (m / 4).max(K + 1), m, s: (ds.n as f64).sqrt() as usize, r: 5 };
    let p = initial_partition(&ds, K, &cfg, &mut rng, &c0);
    let (reps, weights, _) = p.reps_weights();
    let init = weighted_kmeanspp(&reps, &weights, ds.d, K, &mut rng, &c0);
    let m_reps = weights.len();
    println!(
        "=== Ablation A3: pruning (GS sim, n={}, |P|={m_reps}, K={K}) ===",
        ds.n
    );

    let wl_cfg = WLloydCfg { max_iters: 100, tol: 0.0, ..Default::default() };
    let plain = DistanceCounter::new();
    let out_plain = weighted_lloyd(&reps, &weights, ds.d, &init, &wl_cfg, &plain);
    let hamerly = DistanceCounter::new();
    let out_hamerly = pruned_weighted_lloyd(&reps, &weights, ds.d, &init, 100, &hamerly);
    let bounded = DistanceCounter::new();
    let out_bounded = elkan_weighted_lloyd(&reps, &weights, ds.d, &init, 100, &bounded);
    let auto = DistanceCounter::new();
    let mut auto_stepper: EngineStepper<AutoAssigner> = EngineStepper::new();
    let out_auto =
        weighted_lloyd_with(&mut auto_stepper, &reps, &weights, ds.d, &init, &wl_cfg, &auto);

    // Bounded prune rate: fraction of the warm-iteration pair bill the
    // bounds skipped (the priming pass pays m·k by contract).
    let bill = (m_reps * K) as u64;
    let bounded_warm_bill = bill * (out_bounded.iters as u64).saturating_sub(1);
    let bounded_warm_paid = bounded.get().saturating_sub(bill);
    let bounded_prune_rate = if bounded_warm_bill > 0 {
        1.0 - bounded_warm_paid as f64 / bounded_warm_bill as f64
    } else {
        0.0
    };
    // Auto choice summary: the assigner's structured tallies (the
    // counter's note log carries the same per-step choices for replay).
    let auto_summary = auto_stepper.engine().choice_counts().summary();

    // Approximate regime (DESIGN.md §2.9): the same Lloyd run through the
    // closure and sampled backends. These are NOT held to the exact
    // backends' bit-identity contract — they self-report a measured
    // relative gap instead, so they stay out of the drift asserts below.
    let closure_c = DistanceCounter::new();
    let mut closure_stepper = stepper_for(&AssignCfg {
        mode: AssignMode::Closure,
        closure_expand: 2,
        ..Default::default()
    });
    let out_closure = weighted_lloyd_with(
        closure_stepper.as_mut(),
        &reps,
        &weights,
        ds.d,
        &init,
        &wl_cfg,
        &closure_c,
    );
    let closure_gap = closure_stepper
        .quality_gap(&reps, &weights, ds.d, &out_closure.centroids)
        .map(|g| g.rel_gap())
        .unwrap_or(0.0);
    let sampled_c = DistanceCounter::new();
    let mut sampled_stepper = stepper_for(&AssignCfg {
        mode: AssignMode::Sampled,
        sample_rows: (m_reps / 2).max(1),
        ..Default::default()
    });
    let out_sampled = weighted_lloyd_with(
        sampled_stepper.as_mut(),
        &reps,
        &weights,
        ds.d,
        &init,
        &wl_cfg,
        &sampled_c,
    );
    let sampled_gap = sampled_stepper
        .quality_gap(&reps, &weights, ds.d, &out_sampled.centroids)
        .map(|g| g.rel_gap())
        .unwrap_or(0.0);

    let drift = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    };
    let d_h = drift(&out_plain.centroids, &out_hamerly.centroids);
    let d_b = drift(&out_plain.centroids, &out_bounded.centroids);
    let d_a = drift(&out_plain.centroids, &out_auto.centroids);
    let saved = |c: &DistanceCounter| 100.0 * (1.0 - c.get() as f64 / plain.get() as f64);
    println!(
        "{:<10} {:>14} {:>8} {:>8} {:>12}",
        "variant", "distances", "iters", "saved", "prune-rate"
    );
    println!(
        "{:<10} {:>14} {:>8} {:>8} {:>12}",
        "plain", fmt_count(plain.get()), out_plain.iters, "-", "-"
    );
    println!(
        "{:<10} {:>14} {:>8} {:>7.1}% {:>12}",
        "hamerly", fmt_count(hamerly.get()), out_hamerly.iters, saved(&hamerly), "-"
    );
    println!(
        "{:<10} {:>14} {:>8} {:>7.1}% {:>11.1}%",
        "bounded",
        fmt_count(bounded.get()),
        out_bounded.iters,
        saved(&bounded),
        bounded_prune_rate * 100.0
    );
    println!(
        "{:<10} {:>14} {:>8} {:>7.1}% {:>12}",
        "auto", fmt_count(auto.get()), out_auto.iters, saved(&auto), "-"
    );
    println!(
        "{:<10} {:>14} {:>8} {:>7.1}% {:>12}",
        "closure",
        fmt_count(closure_c.get()),
        out_closure.iters,
        saved(&closure_c),
        format!("gap {closure_gap:.1e}")
    );
    println!(
        "{:<10} {:>14} {:>8} {:>7.1}% {:>12}",
        "sampled",
        fmt_count(sampled_c.get()),
        out_sampled.iters,
        saved(&sampled_c),
        format!("gap {sampled_gap:.1e}")
    );
    println!("auto choices: {auto_summary}");
    println!("max centroid drift vs plain: hamerly {d_h:.2e}, bounded {d_b:.2e}, auto {d_a:.2e}");
    assert!(d_h < 1e-6, "hamerly diverged from plain");
    assert!(d_b < 1e-6, "bounded diverged from plain");
    assert!(d_a < 1e-6, "auto diverged from plain");
    // The engine contract's bench-level check (DESIGN.md §2.7): warm
    // bounded iterations must beat the plain bill.
    if out_bounded.iters > 1 {
        assert!(
            bounded_warm_paid < bounded_warm_bill,
            "bounded warm iterations pruned nothing: {bounded_warm_paid} of {bounded_warm_bill}"
        );
    }

    write_csv(
        "ablation_pruning",
        &[
            vec![
                "variant".into(),
                "distances".into(),
                "iters".into(),
                "bounded_prune_rate".into(),
                "auto_choice".into(),
                "rel_gap".into(),
            ],
            vec![
                "plain".into(),
                plain.get().to_string(),
                out_plain.iters.to_string(),
                "".into(),
                "".into(),
                "".into(),
            ],
            vec![
                "hamerly".into(),
                hamerly.get().to_string(),
                out_hamerly.iters.to_string(),
                "".into(),
                "".into(),
                "".into(),
            ],
            vec![
                "bounded".into(),
                bounded.get().to_string(),
                out_bounded.iters.to_string(),
                format!("{bounded_prune_rate:.4}"),
                "".into(),
                "".into(),
            ],
            vec![
                "auto".into(),
                auto.get().to_string(),
                out_auto.iters.to_string(),
                "".into(),
                auto_summary.clone(),
                "".into(),
            ],
            vec![
                "closure".into(),
                closure_c.get().to_string(),
                out_closure.iters.to_string(),
                "".into(),
                "".into(),
                format!("{closure_gap:.6}"),
            ],
            vec![
                "sampled".into(),
                sampled_c.get().to_string(),
                out_sampled.iters.to_string(),
                "".into(),
                "".into(),
                format!("{sampled_gap:.6}"),
            ],
        ],
    );
    // Machine-readable mirror at the repo root (BENCH_ablation_pruning.json):
    // one object per variant — exact variants report rel_gap = 0 by the
    // bit-identity contract just asserted above.
    let jrow = |variant: &str, dists: u64, iters: usize, gap: f64| {
        vec![
            ("variant".to_string(), Cell::from(variant)),
            ("distances".to_string(), Cell::from(dists)),
            ("iters".to_string(), Cell::from(iters)),
            ("rel_gap".to_string(), Cell::from(gap)),
        ]
    };
    write_bench_json(
        "ablation_pruning",
        &[
            jrow("plain", plain.get(), out_plain.iters, 0.0),
            jrow("hamerly", hamerly.get(), out_hamerly.iters, 0.0),
            jrow("bounded", bounded.get(), out_bounded.iters, 0.0),
            jrow("auto", auto.get(), out_auto.iters, 0.0),
            jrow("closure", closure_c.get(), out_closure.iters, closure_gap),
            jrow("sampled", sampled_c.get(), out_sampled.iters, sampled_gap),
        ],
    );
}
