//! Exp A3 — Hamerly distance pruning inside weighted Lloyd (the paper's
//! §4 future-work integration, refs [13]/[15]): plain vs pruned weighted
//! Lloyd over the representatives of a BWKM-like partition of the GS
//! simulator, K = 27. Reports distances actually computed and the
//! reduction factor ([15] reports >80% on favourable data).

use bwkm::bench::{env_f64, write_csv};
use bwkm::bwkm::{initial_partition, InitCfg};
use bwkm::data::simulate;
use bwkm::kmeans::elkan::elkan_weighted_lloyd;
use bwkm::kmeans::init::weighted_kmeanspp;
use bwkm::kmeans::pruning::pruned_weighted_lloyd;
use bwkm::kmeans::{weighted_lloyd, WLloydCfg};
use bwkm::metrics::DistanceCounter;
use bwkm::util::{fmt_count, Rng};

const K: usize = 27;

fn main() {
    let scale = 0.005 * env_f64("BWKM_SCALE", 1.0);
    let ds = simulate("GS", scale, 17).unwrap();
    let mut rng = Rng::new(5);
    let c0 = DistanceCounter::new();
    // A realistic representative set: BWKM's initial partition at 4x the
    // default size (more reps = more pruning opportunity).
    let m = 4 * (10.0 * ((K * ds.d) as f64).sqrt()).ceil() as usize;
    let cfg = InitCfg { m_prime: (m / 4).max(K + 1), m, s: (ds.n as f64).sqrt() as usize, r: 5 };
    let p = initial_partition(&ds, K, &cfg, &mut rng, &c0);
    let (reps, weights, _) = p.reps_weights();
    let init = weighted_kmeanspp(&reps, &weights, ds.d, K, &mut rng, &c0);
    println!(
        "=== Ablation A3: pruning (GS sim, n={}, |P|={}, K={K}) ===",
        ds.n,
        weights.len()
    );

    let plain = DistanceCounter::new();
    let out_plain = weighted_lloyd(
        &reps,
        &weights,
        ds.d,
        &init,
        &WLloydCfg { max_iters: 100, tol: 0.0, ..Default::default() },
        &plain,
    );
    let hamerly = DistanceCounter::new();
    let out_hamerly = pruned_weighted_lloyd(&reps, &weights, ds.d, &init, 100, &hamerly);
    let elkan = DistanceCounter::new();
    let out_elkan = elkan_weighted_lloyd(&reps, &weights, ds.d, &init, 100, &elkan);

    let drift = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    };
    let d_h = drift(&out_plain.centroids, &out_hamerly.centroids);
    let d_e = drift(&out_plain.centroids, &out_elkan.centroids);
    let saved = |c: &DistanceCounter| 100.0 * (1.0 - c.get() as f64 / plain.get() as f64);
    println!("{:<10} {:>14} {:>8} {:>8}", "variant", "distances", "iters", "saved");
    println!("{:<10} {:>14} {:>8} {:>8}", "plain", fmt_count(plain.get()), out_plain.iters, "-");
    println!(
        "{:<10} {:>14} {:>8} {:>7.1}%",
        "hamerly", fmt_count(hamerly.get()), out_hamerly.iters, saved(&hamerly)
    );
    println!(
        "{:<10} {:>14} {:>8} {:>7.1}%",
        "elkan", fmt_count(elkan.get()), out_elkan.iters, saved(&elkan)
    );
    println!("max centroid drift vs plain: hamerly {d_h:.2e}, elkan {d_e:.2e}");
    assert!(d_h < 1e-6, "hamerly diverged from plain");
    assert!(d_e < 1e-6, "elkan diverged from plain");

    write_csv(
        "ablation_pruning",
        &[
            vec!["variant".into(), "distances".into(), "iters".into()],
            vec!["plain".into(), plain.get().to_string(), out_plain.iters.to_string()],
            vec!["hamerly".into(), hamerly.get().to_string(), out_hamerly.iters.to_string()],
            vec!["elkan".into(), elkan.get().to_string(), out_elkan.iters.to_string()],
        ],
    );
}
