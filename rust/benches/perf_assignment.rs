//! Exp P1 — hot-path throughput of the assignment step (the cost center of
//! every method): the unified engine's serial backend (`NativeStepper`)
//! vs sharded vs norm-pruned vs cross-iteration bounded vs auto-selected
//! vs PJRT artifacts vs Hamerly-pruned, swept over (m, K, d). All engine
//! backends produce bit-identical output (DESIGN.md §2), so the columns
//! differ only in time and — for the pruned ones — distance count.
//! Reports representative-rows/s, the fraction of the n·k distance bill
//! each pruned backend actually paid (norm-pruned per pass; bounded on
//! the *second* weighted-Lloyd iteration, i.e. the first warm one —
//! gaussian clouds are the adversarial case, real partitions prune much
//! harder), and the backend `AutoAssigner` settled on. Feeds
//! EXPERIMENTS.md §Perf.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use bwkm::bench::{bench_secs, env_f64, write_bench_json, write_csv, Cell};
use bwkm::coordinator::{sharded_weighted_step, ShardedStepper};
use bwkm::kmeans::assign::{
    weighted_step, Assigner, AutoAssigner, BoundedAssigner, ClosureAssigner,
};
use bwkm::kmeans::{
    KernelKind, NativeStepper, NormPrunedAssigner, Precision, SampledStepper, StepOut, Stepper,
    VectorAssigner,
};
use bwkm::metrics::DistanceCounter;
use bwkm::runtime::Runtime;
use bwkm::util::{fmt_count, Rng};

/// Counting allocator (DESIGN.md §2.12): tallies every heap allocation so
/// the warm-vs-cold rows can report allocs/step — the steady-state
/// guarantee is warm exact steps at **zero**.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

/// Allocations `f` performed (process-wide; run with other threads idle).
fn allocs_in(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

fn main() {
    let mult = env_f64("BWKM_SCALE", 1.0);
    let sweeps: Vec<(usize, usize, usize)> = vec![
        ((2_000 as f64 * mult) as usize, 3, 3),
        ((2_000 as f64 * mult) as usize, 27, 19),
        ((16_000 as f64 * mult) as usize, 9, 5),
        ((16_000 as f64 * mult) as usize, 27, 19),
    ];
    let mut runtime = Runtime::open_default().ok();
    if runtime.is_none() {
        eprintln!("(no artifacts found; PJRT column skipped — run `make artifacts`)");
    }

    println!("=== P1: assignment-step throughput (rows/s, one weighted-Lloyd step) ===");
    println!(
        "{:<18} {:>10} {:>12} {:>16} {:>16} {:>16} {:>16} {:>12} {:>12} {:>12} {:>14}",
        "m,k,d",
        "native",
        "sharded(4)",
        "normprune(bill)",
        "bounded(bill)",
        "closure(bill)",
        "sampled(bill)",
        "auto",
        "pjrt",
        "pruned-run",
        "dists/s native"
    );
    let mut rows = vec![vec![
        "m".into(),
        "k".into(),
        "d".into(),
        "native_rows_s".into(),
        "sharded_rows_s".into(),
        "normprune_rows_s".into(),
        "normprune_bill_frac".into(),
        "bounded_rows_s".into(),
        "bounded_bill_frac".into(),
        "closure_rows_s".into(),
        "closure_bill_frac".into(),
        "closure_rel_gap".into(),
        "sampled_rows_s".into(),
        "sampled_bill_frac".into(),
        "sampled_rel_gap".into(),
        "auto_choice".into(),
        "pjrt_rows_s".into(),
        "pruned_rows_s".into(),
        "simd_rows_s".into(),
        "f32_rows_s".into(),
        "f32_rel_gap".into(),
        "warm_rows_s".into(),
        "warm_sharded_rows_s".into(),
        "allocs_cold_step".into(),
        "allocs_warm_step".into(),
    ]];
    // Machine-readable rows (BENCH_assignment.json at the repo root),
    // each tagged with the §2.10 kernel/precision the measurement ran on.
    let mut jrows: Vec<Vec<(String, Cell)>> = Vec::new();
    for (m, k, d) in sweeps {
        let mut rng = Rng::new(3);
        let reps: Vec<f64> = (0..m * d).map(|_| rng.normal() * 3.0).collect();
        let weights: Vec<f64> = (0..m).map(|_| 1.0 + rng.usize(50) as f64).collect();
        let cents: Vec<f64> = (0..k * d).map(|_| rng.normal() * 3.0).collect();
        let c = DistanceCounter::new();

        let t_native = bench_secs(3, || {
            let mut s = NativeStepper::new();
            std::hint::black_box(s.step(&reps, &weights, d, &cents, &c));
        });
        let t_shard = bench_secs(3, || {
            std::hint::black_box(sharded_weighted_step(&reps, &weights, d, &cents, 4, &c));
        });

        // Warm vs cold steady state (DESIGN.md §2.12): cold pays a fresh
        // stepper and a fresh output per step (t_native above); warm holds
        // one stepper and one `StepOut` and refills them through
        // `step_into`. The allocs/step column is the point — the warm
        // exact serial step is pinned at zero by pool_conformance.rs.
        let mut warm_stepper = NativeStepper::new();
        let mut warm_out = StepOut::default();
        warm_stepper.step_into(&reps, &weights, d, &cents, &c, &mut warm_out); // prime
        let t_warm = bench_secs(3, || {
            warm_stepper.step_into(&reps, &weights, d, &cents, &c, &mut warm_out);
            std::hint::black_box(&warm_out);
        });
        let allocs_cold = allocs_in(|| {
            let mut s = NativeStepper::new();
            std::hint::black_box(s.step(&reps, &weights, d, &cents, &c));
        });
        let allocs_warm = allocs_in(|| {
            warm_stepper.step_into(&reps, &weights, d, &cents, &c, &mut warm_out);
        });
        // The same warm step fanned over the shared pool (pool=on rows):
        // persistent ShardedStepper, reused output arena.
        let mut pool_stepper = ShardedStepper::new(4);
        let mut pool_out = StepOut::default();
        pool_stepper.step_into(&reps, &weights, d, &cents, &c, &mut pool_out); // prime
        let t_pool_warm = bench_secs(3, || {
            pool_stepper.step_into(&reps, &weights, d, &cents, &c, &mut pool_out);
            std::hint::black_box(&pool_out);
        });
        let t_normprune = bench_secs(3, || {
            std::hint::black_box(weighted_step(
                &mut NormPrunedAssigner::new(),
                &reps,
                &weights,
                d,
                &cents,
                &c,
            ));
        });
        // Fraction of the n·k pair bill actually evaluated, net of the
        // documented m + k norm overhead (DESIGN.md §2.4), so 100% means
        // "pruned nothing" (gaussian clouds are an adversarial case for
        // norm pruning — real partitions with separated blocks prune much
        // harder).
        let c_np = DistanceCounter::new();
        let _ = weighted_step(&mut NormPrunedAssigner::new(), &reps, &weights, d, &cents, &c_np);
        let pairs = c_np.get().saturating_sub((m + k) as u64);
        let bill_frac = pairs as f64 / (m as f64 * k as f64);

        // Bounded: throughput of the steady-state warm step (the backend's
        // whole point is the cross-iteration regime), and the bill
        // fraction of the *first* warm iteration of a real Lloyd
        // trajectory (cold prime → update → warm step).
        let mut bounded_steady = BoundedAssigner::new();
        let c_b = DistanceCounter::new();
        let _ = weighted_step(&mut bounded_steady, &reps, &weights, d, &cents, &c_b);
        let t_bounded = bench_secs(3, || {
            std::hint::black_box(weighted_step(
                &mut bounded_steady,
                &reps,
                &weights,
                d,
                &cents,
                &c_b,
            ));
        });
        let mut bounded_traj = BoundedAssigner::new();
        let c_bt = DistanceCounter::new();
        let step1 = weighted_step(&mut bounded_traj, &reps, &weights, d, &cents, &c_bt);
        let _ = weighted_step(&mut bounded_traj, &reps, &weights, d, &step1.centroids, &c_bt);
        let b_stats = bounded_traj.last_stats();
        let b_bill_frac = b_stats.pairs as f64 / (m as f64 * k as f64);

        // Approximate regime (DESIGN.md §2.9): closure candidates in the
        // warm steady state (a total/non-amortizing closure honestly
        // reports bill_frac = 1 — it falls back to exact), and the
        // sampled stepper at half the rows. Both report the fraction of
        // the m·k bill actually charged plus their measured relative gap.
        let mut closure = ClosureAssigner::new(2);
        let c_cl = DistanceCounter::new();
        let _ = weighted_step(&mut closure, &reps, &weights, d, &cents, &c_cl); // cold prime
        let t_closure = bench_secs(3, || {
            std::hint::black_box(weighted_step(&mut closure, &reps, &weights, d, &cents, &c_cl));
        });
        let cl_stats = closure.last_stats();
        let cl_bill_frac = (cl_stats.pairs + cl_stats.bookkeeping) as f64 / (m as f64 * k as f64);
        let cl_gap = closure
            .quality_gap(&reps, Some(&weights), d, &cents)
            .map(|gp| gp.rel_gap())
            .unwrap_or(0.0);

        let mut sampled = SampledStepper::new(m / 2, 0xB16D);
        let c_sp = DistanceCounter::new();
        let _ = sampled.step(&reps, &weights, d, &cents, &c_sp); // cold prime
        let t_sampled = bench_secs(3, || {
            std::hint::black_box(sampled.step(&reps, &weights, d, &cents, &c_sp));
        });
        let sp_stats = sampled.last_stats();
        let sp_bill_frac = sp_stats.pairs as f64 / (m as f64 * k as f64);
        let sp_gap = Stepper::quality_gap(&mut sampled, &reps, &weights, d, &cents)
            .map(|gp| gp.rel_gap())
            .unwrap_or(0.0);

        // Vectorized engine (DESIGN.md §2.10): the explicit-lane f64
        // kernel (pinned bit-identical to native — this is a pure
        // throughput column) and the mixed-precision f32 mode, whose
        // relative werr gap against the exact step is reported alongside.
        let mut vec_simd = VectorAssigner::new(KernelKind::Simd, Precision::F64);
        let t_simd = bench_secs(3, || {
            std::hint::black_box(weighted_step(&mut vec_simd, &reps, &weights, d, &cents, &c));
        });
        let mut vec_f32 = VectorAssigner::new(KernelKind::Simd, Precision::F32);
        let t_f32 = bench_secs(3, || {
            std::hint::black_box(weighted_step(&mut vec_f32, &reps, &weights, d, &cents, &c));
        });
        let werr_exact =
            weighted_step(&mut bwkm::kmeans::SerialAssigner, &reps, &weights, d, &cents, &c).werr;
        let werr_f32 = weighted_step(&mut vec_f32, &reps, &weights, d, &cents, &c).werr;
        let f32_gap = (werr_f32 - werr_exact).abs() / werr_exact.max(f64::MIN_POSITIVE);

        // Auto: what the selector settles on for this shape after a short
        // warm sequence (choices also land in the counter's note log).
        let mut auto = AutoAssigner::new();
        let c_a = DistanceCounter::new();
        let mut a_cents = cents.clone();
        for _ in 0..3 {
            a_cents = weighted_step(&mut auto, &reps, &weights, d, &a_cents, &c_a).centroids;
        }
        let auto_choice = auto.last_choice();
        let t_pjrt = runtime.as_mut().map(|rt| {
            bench_secs(3, || {
                std::hint::black_box(rt.wlloyd_step(&reps, &weights, d, &cents).unwrap());
            })
        });
        // Pruned runs a whole convergence loop; report rows/s per iteration.
        let mut iters = 1usize;
        let t_pruned = bench_secs(1, || {
            let out = bwkm::kmeans::pruning::pruned_weighted_lloyd(
                &reps, &weights, d, &cents, 30, &c,
            );
            iters = out.iters.max(1);
            std::hint::black_box(out);
        }) / iters as f64;

        let rps = |t: f64| m as f64 / t;
        println!(
            "{:<18} {:>10} {:>12} {:>16} {:>16} {:>16} {:>16} {:>12} {:>12} {:>12} {:>14}",
            format!("{m},{k},{d}"),
            fmt_count(rps(t_native) as u64),
            fmt_count(rps(t_shard) as u64),
            format!("{} ({:.0}%)", fmt_count(rps(t_normprune) as u64), bill_frac * 100.0),
            format!("{} ({:.0}%)", fmt_count(rps(t_bounded) as u64), b_bill_frac * 100.0),
            format!("{} ({:.0}%)", fmt_count(rps(t_closure) as u64), cl_bill_frac * 100.0),
            format!("{} ({:.0}%)", fmt_count(rps(t_sampled) as u64), sp_bill_frac * 100.0),
            auto_choice,
            t_pjrt.map(|t| fmt_count(rps(t) as u64)).unwrap_or_else(|| "-".into()),
            fmt_count(rps(t_pruned) as u64),
            fmt_count((rps(t_native) * k as f64) as u64),
        );
        println!(
            "{:<18} vector: simd-f64 {} rows/s, simd-f32 {} rows/s (f32 rel gap {:.1e})",
            "", fmt_count(rps(t_simd) as u64), fmt_count(rps(t_f32) as u64), f32_gap
        );
        println!(
            "{:<18} steady state: cold {} rows/s ({} allocs/step), warm {} rows/s ({} allocs/step), warm sharded(4) {} rows/s",
            "",
            fmt_count(rps(t_native) as u64),
            allocs_cold,
            fmt_count(rps(t_warm) as u64),
            allocs_warm,
            fmt_count(rps(t_pool_warm) as u64),
        );
        rows.push(vec![
            m.to_string(),
            k.to_string(),
            d.to_string(),
            format!("{:.0}", rps(t_native)),
            format!("{:.0}", rps(t_shard)),
            format!("{:.0}", rps(t_normprune)),
            format!("{:.4}", bill_frac),
            format!("{:.0}", rps(t_bounded)),
            format!("{:.4}", b_bill_frac),
            format!("{:.0}", rps(t_closure)),
            format!("{:.4}", cl_bill_frac),
            format!("{:.4e}", cl_gap),
            format!("{:.0}", rps(t_sampled)),
            format!("{:.4}", sp_bill_frac),
            format!("{:.4e}", sp_gap),
            auto_choice.to_string(),
            t_pjrt.map(|t| format!("{:.0}", rps(t))).unwrap_or_default(),
            format!("{:.0}", rps(t_pruned)),
            format!("{:.0}", rps(t_simd)),
            format!("{:.0}", rps(t_f32)),
            format!("{:.4e}", f32_gap),
            format!("{:.0}", rps(t_warm)),
            format!("{:.0}", rps(t_pool_warm)),
            allocs_cold.to_string(),
            allocs_warm.to_string(),
        ]);
        // Typed cells (explicit per-cell JSON types — see bench::Cell):
        // backend/kernel/precision are strings, the sweep shape integers,
        // the measurements floats.
        let jrow = |backend: &str, kernel: KernelKind, precision: Precision, secs: f64,
                    frac: f64, gap: f64, pool: &str| {
            vec![
                ("backend".to_string(), Cell::from(backend)),
                ("kernel".to_string(), Cell::from(kernel.name())),
                ("precision".to_string(), Cell::from(precision.name())),
                ("pool".to_string(), Cell::from(pool)),
                ("m".to_string(), Cell::from(m)),
                ("k".to_string(), Cell::from(k)),
                ("d".to_string(), Cell::from(d)),
                ("rows_per_s".to_string(), Cell::from(rps(secs))),
                ("bill_frac".to_string(), Cell::from(frac)),
                ("rel_gap".to_string(), Cell::from(gap)),
            ]
        };
        jrows.push(jrow("exact", KernelKind::Scalar, Precision::F64, t_native, 1.0, 0.0, "off"));
        jrows.push(jrow("exact", KernelKind::Simd, Precision::F64, t_simd, 1.0, 0.0, "off"));
        jrows.push(jrow("exact", KernelKind::Simd, Precision::F32, t_f32, 1.0, f32_gap, "off"));
        jrows.push(jrow(
            "closure",
            KernelKind::Scalar,
            Precision::F64,
            t_closure,
            cl_bill_frac,
            cl_gap,
            "off",
        ));
        jrows.push(jrow(
            "sampled",
            KernelKind::Scalar,
            Precision::F64,
            t_sampled,
            sp_bill_frac,
            sp_gap,
            "off",
        ));
        jrows.push(jrow("sharded", KernelKind::Scalar, Precision::F64, t_shard, 1.0, 0.0, "on"));
        // Steady-state rows (DESIGN.md §2.12): warm arena steps, with the
        // measured allocations per step attached.
        let mut warm_cold =
            jrow("exact_cold", KernelKind::Scalar, Precision::F64, t_native, 1.0, 0.0, "off");
        warm_cold.push(("allocs_per_step".to_string(), Cell::from(allocs_cold)));
        jrows.push(warm_cold);
        let mut warm_row =
            jrow("exact_warm", KernelKind::Scalar, Precision::F64, t_warm, 1.0, 0.0, "off");
        warm_row.push(("allocs_per_step".to_string(), Cell::from(allocs_warm)));
        jrows.push(warm_row);
        jrows.push(jrow(
            "sharded_warm",
            KernelKind::Scalar,
            Precision::F64,
            t_pool_warm,
            1.0,
            0.0,
            "on",
        ));
    }
    write_csv("perf_assignment", &rows);
    write_bench_json("assignment", &jrows);
}
