//! Exp A1 — ablation of BWKM's splitting criterion (the answer to the
//! paper's Problems 2/3): boundary-guided ε-sampled splitting (BWKM)
//! vs splitting *every* block (grid-RPKM-like) vs splitting uniformly at
//! random, at matched distance budgets on the 3RN simulator, K = 9.
//!
//! Expected shape (paper §1.3): the boundary criterion reaches a given
//! error with substantially fewer representatives / distances because it
//! spends splits only where cluster affiliation is ambiguous.

use bwkm::bwkm::{run as bwkm_run, BwkmCfg};
use bwkm::data::simulate;
use bwkm::bench::{env_f64, env_u64, write_csv};
use bwkm::kmeans::init::weighted_kmeanspp;
use bwkm::kmeans::{weighted_lloyd, WLloydCfg};
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::partition::Partition;
use bwkm::rpkm::{grid_rpkm, RpkmCfg};
use bwkm::util::{fmt_count, Cdf, Rng};

const K: usize = 9;

fn main() {
    let scale = 0.05 * env_f64("BWKM_SCALE", 1.0);
    let reps = env_u64("BWKM_REPS", 3);
    let ds = simulate("3RN", scale, 11).unwrap();
    println!("=== Ablation A1: splitting criterion (3RN sim, n={}, K={K}) ===", ds.n);
    println!("{:<18} {:>14} {:>12} {:>8}", "strategy", "distances", "E^D", "|P|");

    let mut rows = vec![vec![
        "strategy".into(),
        "rep".into(),
        "distances".into(),
        "error".into(),
        "blocks".into(),
    ]];
    for rep in 0..reps {
        // --- BWKM (boundary-guided).
        let c = DistanceCounter::new();
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, K);
        cfg.max_outer = 14;
        let out = bwkm_run(&ds, K, &cfg, &mut Rng::new(100 + rep), &c);
        let eval = DistanceCounter::new();
        let e = kmeans_error(&ds.data, ds.d, &out.centroids, &eval);
        report(&mut rows, "boundary (BWKM)", rep, c.get(), e, out.partition.occupied());

        // --- Split-all (grid-RPKM).
        let c = DistanceCounter::new();
        let rcfg = RpkmCfg { max_levels: 7, ..Default::default() };
        let out = grid_rpkm(&ds, K, &rcfg, &mut Rng::new(100 + rep), &c);
        let eval = DistanceCounter::new();
        let e = kmeans_error(&ds.data, ds.d, &out.centroids, &eval);
        let m = out.trace.last().unwrap().representatives;
        report(&mut rows, "split-all (RPKM)", rep, c.get(), e, m);

        // --- Random splitting with the same outer loop shape as BWKM.
        let c = DistanceCounter::new();
        let (e, m) = random_split_run(&ds, 14, &mut Rng::new(100 + rep), &c);
        report(&mut rows, "random-split", rep, c.get(), e, m);
    }
    write_csv("ablation_split", &rows);
}

fn report(rows: &mut Vec<Vec<String>>, name: &str, rep: u64, d: u64, e: f64, m: usize) {
    println!("{:<18} {:>14} {:>12.5e} {:>8}", name, fmt_count(d), e, m);
    rows.push(vec![
        name.into(),
        rep.to_string(),
        d.to_string(),
        format!("{e:.8e}"),
        m.to_string(),
    ]);
}

/// BWKM's outer loop but with uniformly-random block selection (the same
/// number of splits per round as blocks in the boundary would allow).
fn random_split_run(
    ds: &bwkm::data::Dataset,
    outers: usize,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> (f64, usize) {
    let mut partition = Partition::root(ds);
    // Match BWKM's initial partition size.
    let cfg = BwkmCfg::for_dataset(ds.n, ds.d, K);
    while partition.len() < cfg.init.m {
        let weights: Vec<f64> =
            partition.blocks.iter().map(|b| b.weight() as f64).collect();
        let cdf = match Cdf::new(&weights) {
            Some(c) => c,
            None => break,
        };
        let b = cdf.sample(rng);
        if partition.blocks[b].weight() > 1 {
            partition.split(b, ds);
        }
    }
    let (mut reps, mut weights, _) = partition.reps_weights();
    let mut cents = weighted_kmeanspp(&reps, &weights, ds.d, K, rng, counter);
    for _ in 0..outers {
        let out = weighted_lloyd(&reps, &weights, ds.d, &cents, &WLloydCfg::default(), counter);
        cents = out.centroids;
        // Random splits: as many as there are blocks (uniform).
        let rounds = partition.len();
        for _ in 0..rounds.min(64) {
            let b = rng.usize(partition.len());
            if partition.blocks[b].weight() > 1 {
                partition.split(b, ds);
            }
        }
        let rw = partition.reps_weights();
        reps = rw.0;
        weights = rw.1;
    }
    let eval = DistanceCounter::new();
    (kmeans_error(&ds.data, ds.d, &cents, &eval), partition.occupied())
}
