//! Minimal offline stand-in for the `anyhow` crate (DESIGN.md §4: the
//! crates.io mirror is unavailable, so the one error-handling dependency
//! is vendored as this shim). It implements exactly the surface the bwkm
//! crate uses: [`Error`], [`Result`], `anyhow!`, `bail!`, `ensure!`, and
//! the [`Context`] extension for `Result` and `Option`.
//!
//! Semantics are intentionally simplified relative to upstream: the error
//! is a flattened message string (context is prepended as
//! `"context: cause"`) rather than a source chain, and there is no
//! downcasting — nothing in this repository uses either.

use std::fmt;

/// Flattened-message error type (stand-in for `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (stand-in for `Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// The same blanket conversion upstream anyhow provides; coherent because
// `Error` itself deliberately does NOT implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result` alias: `Result<T>` defaults the error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failure (stand-in for `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error/none case with a fixed context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display;

    /// Wrap the error/none case with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display,
    {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (stand-in for `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))` (stand-in for `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless a condition holds (stand-in for `ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &str) -> Result<usize> {
        let n: usize = v.parse().context("not a number")?;
        if n == 0 {
            bail!("zero is not allowed (got `{v}`)");
        }
        Ok(n)
    }

    #[test]
    fn context_and_bail_and_question_mark() {
        assert_eq!(parse("7").unwrap(), 7);
        assert_eq!(parse("x").unwrap_err().to_string(), "not a number: invalid digit found in string");
        assert_eq!(parse("0").unwrap_err().to_string(), "zero is not allowed (got `0`)");
    }

    #[test]
    fn ensure_bails_on_false_only() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n % 2 == 0, "odd value {n}");
            Ok(n)
        }
        assert_eq!(check(4).unwrap(), 4);
        assert_eq!(check(3).unwrap_err().to_string(), "odd value 3");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/real/path/42")?)
        }
        let e = io().unwrap_err();
        assert!(!e.to_string().is_empty());
        assert_eq!(format!("{e}"), format!("{e:?}"));
    }
}
