//! Offline stub of the `xla` crate surface `bwkm::runtime` uses
//! (DESIGN.md §4). The real PJRT bindings cannot be built without network
//! access and a PJRT plugin, so every entry point reports unavailability.
//! The runtime then degrades exactly as it does when AOT artifacts are
//! absent: `Runtime::open` fails, `PjrtStepper` is never constructed (or
//! its `wlloyd_step` errors and the native fallback serves the step), the
//! benches print their "PJRT column skipped" note, and `bwkm info`
//! reports "no artifacts found". Swap this path dependency for the real
//! `xla` crate to light the device path up — no bwkm source changes
//! needed (the type surface matches what the runtime calls).

use std::fmt;

/// Stub error carrying a fixed unavailability message; the runtime only
/// ever formats it with `{:?}`.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> Error {
    Error("vendored offline xla stub: no PJRT runtime in this build".to_string())
}

/// Stub of the PJRT CPU client; construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Stub of a parsed HLO module; parsing always fails.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stub computation wrapper (constructible — it carries no state).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub compiled executable; execution always fails.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stub host literal. Shape-free: construction/reshape succeed (they are
/// pure host bookkeeping) and every data access fails.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}
